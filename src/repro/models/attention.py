"""GQA attention: training (full sequence), prefill, and cached decode.

Design notes (TPU adaptation):
  * GQA is computed by reshaping query heads into [kv_heads, group] so
    the einsum contracts against un-repeated K/V — no materialized
    repeat_kv, which matters when kv_heads << heads (starcoder2 kv=2).
  * ``attn_impl="chunked"`` is a flash-style lazy-softmax over KV chunks
    (running max/denominator) — the sub-quadratic-memory path used by
    long sequences; "dense" materializes [B, H, S, S] and is fine at
    train_4k.
  * Decode: one query token against a [B, S_max, kv, hd] cache with a
    position mask; cache layout keeps seq minor-adjacent to heads so the
    update is a dynamic_update_slice on a contiguous block.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_constraint
from repro.models.layers import apply_rope

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B, S, KH, G, hd], k: [B, T, KH, hd] -> [B, KH, G, S, T]."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


def _gqa_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: [B, KH, G, S, T], v: [B, T, KH, hd] -> [B, S, KH, G, hd]."""
    return jnp.einsum("bkgst,btkd->bskgd", p, v)


def _causal_mask(s: int, t: int, offset: int = 0,
                 window: int = 0) -> jax.Array:
    """[S, T] True = visible.  offset positions precede the queries."""
    qpos = jnp.arange(s)[:, None] + offset
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > (qpos - window)
    return mask


def dense_attention(
    q: jax.Array,              # [B, S, H, hd]
    k: jax.Array,              # [B, T, KH, hd]
    v: jax.Array,              # [B, T, KH, hd]
    *,
    causal: bool,
    window: int = 0,
    q_offset: int = 0,
    kv_valid_len: Optional[jax.Array] = None,   # [B] for decode masking
) -> jax.Array:
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scores = _gqa_scores(qg, k) / jnp.sqrt(hd).astype(q.dtype)
    mask = None
    if causal:
        mask = _causal_mask(s, t, q_offset, window)[None, None, None]
    if kv_valid_len is not None:
        valid = jnp.arange(t)[None, :] < kv_valid_len[:, None]   # [B, T]
        valid = valid[:, None, None, None, :]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = _gqa_out(p, v)
    return out.reshape(b, s, h, hd)


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_offset: int = 0,
    chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style lazy softmax over KV chunks: O(S * chunk) live scores."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    n_chunks = (t + chunk - 1) // chunk
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kh, hd)
    vc = v.reshape(b, n_chunks, chunk, kh, hd)
    scale = 1.0 / jnp.sqrt(hd)

    def _pin(m, l, acc):
        # Pin the scan carry's sharding: unconstrained, GSPMD replicates
        # the fp32 accumulator (measured 21.5 GiB/device at qwen
        # prefill_32k).  Query-seq shards over "model" (context
        # parallelism) because kv-head counts rarely divide the axis.
        m = shard_constraint(m, "batch", "kv_heads", None, "attn_q_seq")
        l = shard_constraint(l, "batch", "kv_heads", None, "attn_q_seq")
        acc = shard_constraint(acc, "batch", "kv_heads", None,
                               "attn_q_seq", None)
        return m, l, acc

    def body(carry, inputs):
        m, l, acc = carry                      # running max / denom / numerator
        kci, vci, ci = inputs
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kci) * scale
        scores = shard_constraint(scores, "batch", "kv_heads", None,
                                  "attn_q_seq", None)
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        qpos = jnp.arange(s)[:, None] + q_offset
        mask = kpos < t + 0 * kpos             # drop the zero-padding
        if causal:
            mask = mask & (kpos <= qpos)
            if window > 0:
                mask = mask & (kpos > qpos - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p, vci)
        return _pin(m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s), jnp.float32)
    acc0 = jnp.zeros((b, kh, g, s, hd), jnp.float32)
    m0, l0, acc0 = _pin(m0, l0, acc0)
    # unroll=True flattens the chunk loop (used by the dry-run cost
    # probes: cost_analysis counts while bodies once)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)),
        unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd).astype(q.dtype)


class KVCache(NamedTuple):
    """Ring-buffer KV cache.

    ``pos[s]`` is the absolute token position stored in slot ``s`` (-1 =
    empty).  Full-attention models allocate S_max >= total length, so the
    ring never wraps; sliding-window models allocate S_max = window and
    the ring semantics give an O(window) decode state (what qualifies
    hymba for long_500k)."""
    k: jax.Array          # [B, S_max, KH, hd]
    v: jax.Array          # [B, S_max, KH, hd]
    pos: jax.Array        # [S_max] int32 absolute positions, -1 empty
    length: jax.Array     # [] int32 — tokens seen so far


def init_kv_cache(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_seq, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_seq, kv_heads, head_dim), dtype),
        pos=jnp.full((max_seq,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def cache_pos_update(pos: jax.Array, length: jax.Array, s_new: int) -> jax.Array:
    """Position-buffer half of cache_update (shared across layers)."""
    s_max = pos.shape[0]
    if s_new >= s_max:
        tail_pos = length + jnp.arange(s_new - s_max, s_new)
        shift = tail_pos[0] % s_max
        return jnp.roll(tail_pos, shift).astype(jnp.int32)
    new_pos = length + jnp.arange(s_new)
    slots = (new_pos % s_max).astype(jnp.int32)
    return pos.at[slots].set(new_pos.astype(jnp.int32))


def cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array) -> KVCache:
    """Append S_new tokens starting at absolute position cache.length.
    Slots wrap modulo S_max (ring buffer); if S_new >= S_max only the
    last S_max tokens are kept."""
    s_max = cache.k.shape[1]
    s_new = k_new.shape[1]
    pos = cache_pos_update(cache.pos, cache.length, s_new)
    if s_new >= s_max:
        # keep only the tail; lay it out so slot == pos % s_max
        tail_pos = cache.length + jnp.arange(s_new - s_max, s_new)
        k_tail = k_new[:, -s_max:].astype(cache.k.dtype)
        v_tail = v_new[:, -s_max:].astype(cache.v.dtype)
        shift = tail_pos[0] % s_max
        k = jnp.roll(k_tail, shift, axis=1)
        v = jnp.roll(v_tail, shift, axis=1)
        return KVCache(k, v, pos, cache.length + s_new)
    new_pos = cache.length + jnp.arange(s_new)
    slots = (new_pos % s_max).astype(jnp.int32)
    k = cache.k.at[:, slots].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[:, slots].set(v_new.astype(cache.v.dtype))
    return KVCache(k, v, pos, cache.length + s_new)


def attention_apply(
    p: dict,                       # attn params
    x: jax.Array,                  # [B, S, d_model]
    *,
    cfg,
    positions: jax.Array,          # [B, S] or [S]
    cache: Optional[KVCache] = None,
    causal: bool = True,
    window: int = 0,
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Self-attention with optional KV cache (decode/prefill)."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    # TP over heads when the head count divides the model axis;
    # otherwise sequence parallelism (seq always divides our shapes).
    # An explicit None constraint REPLICATES the dim — measured 3.7x
    # per-device HLO flops on smollm (15 heads on a 16-way axis) when
    # attention fell back to replication.
    from repro.distributed.sharding import mesh_axis_size
    msize = mesh_axis_size("model")
    heads_divide = bool(msize) and cfg.n_heads % msize == 0 and \
        cfg.n_kv_heads % msize == 0
    if msize is None or heads_divide:
        q = shard_constraint(q, "batch", "seq", "heads", None)
        k = shard_constraint(k, "batch", "seq", "kv_heads", None)
        v = shard_constraint(v, "batch", "seq", "kv_heads", None)
    elif s > 1:
        q = shard_constraint(q, "batch", "attn_q_seq", None, None)
        k = shard_constraint(k, "batch", "attn_q_seq", None, None)
        v = shard_constraint(v, "batch", "attn_q_seq", None, None)
    if use_rope:
        if positions.ndim == 1:
            positions = positions[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        new_cache = cache_update(cache, k, v)
        if s > 1:
            # prefill: queries attend over the fresh K/V directly (the
            # ring buffer may hold only the window tail, which would be
            # wrong for early queries); cache starts empty in this flow.
            if cfg.attn_impl == "chunked":
                out = chunked_attention(q, k, v, causal=causal, window=window,
                                        unroll=not cfg.scan_layers)
            else:
                out = dense_attention(q, k, v, causal=causal, window=window)
        else:
            out = _decode_attention(q, new_cache, window=window)
    elif cfg.attn_impl == "chunked":
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                unroll=not cfg.scan_layers)
    else:
        out = dense_attention(q, k, v, causal=causal, window=window)

    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = jnp.einsum("bsq,qd->bsd", out, p["wo"])
    return out, new_cache


def _decode_attention(q, cache: KVCache, *, window: int) -> jax.Array:
    """One-token attention against the ring buffer: slot validity and
    causality come from the stored absolute positions."""
    b, s, h, hd = q.shape
    kh = cache.k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, hd)
    scores = _gqa_scores(qg, cache.k.astype(q.dtype)) / jnp.sqrt(hd).astype(q.dtype)
    qpos = cache.length - 1                       # position of the new token
    kpos = cache.pos[None, :]                     # [1, S_max]
    mask = (kpos >= 0) & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = _gqa_out(p, cache.v.astype(q.dtype))
    return out.reshape(b, s, h, hd)


def cross_attention_apply(
    p: dict,
    x: jax.Array,                  # [B, S, d_model] decoder side
    enc: jax.Array,                # [B, T, d_model] encoder / vision side
    *,
    cfg,
) -> jax.Array:
    b, s, _ = x.shape
    t = enc.shape[1]
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("btd,dk->btk", enc, p["wk"]).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("btd,dk->btk", enc, p["wv"]).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim)
    out = dense_attention(q, k, v, causal=False)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"])
