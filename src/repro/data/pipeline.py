"""LM batch pipeline + similarity-driven training-data sampler.

``LMBatchPipeline`` packs a ShardedCorpus into (batch, seq_len) token
blocks with next-token labels — the input format for every architecture
in the zoo.  Shards are the unit of shuffling and of similarity-driven
selection, mirroring the query path.

``SimilaritySampler`` is the beyond-paper integration of EmApprox into
*training*: given an approximation index and a "domain prompt", shards
are drawn with pps probabilities so gradient steps concentrate on
query-relevant data (DESIGN.md Sec. 4).
"""
from __future__ import annotations

import dataclasses
import threading
import queue as queue_mod
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.data.store import ShardedCorpus


@dataclasses.dataclass
class LMBatchPipeline:
    corpus: ShardedCorpus
    batch_size: int
    seq_len: int
    pad_id: int = 0
    seed: int = 0
    shard_order: Optional[Sequence[int]] = None  # None = shuffled each epoch

    def _shard_sequence(self, epoch: int) -> np.ndarray:
        if self.shard_order is not None:
            return np.asarray(self.shard_order, np.int64)
        rng = np.random.default_rng(self.seed + epoch)
        return rng.permutation(self.corpus.n_shards)

    def iter_epoch(self, epoch: int = 0) -> Iterator[dict]:
        """Yields {'tokens': int32 [B, S], 'labels': int32 [B, S],
        'mask': float32 [B, S]} — labels are next-token shifted."""
        need = self.batch_size * (self.seq_len + 1)
        buf = np.zeros(0, np.int32)
        for sid in self._shard_sequence(epoch):
            shard = self.corpus.shards[int(sid)]
            buf = np.concatenate([buf, shard.tokens])
            while buf.shape[0] >= need:
                block = buf[:need].reshape(self.batch_size, self.seq_len + 1)
                buf = buf[need:]
                yield {
                    "tokens": block[:, :-1].copy(),
                    "labels": block[:, 1:].copy(),
                    "mask": np.ones((self.batch_size, self.seq_len), np.float32),
                }
        if buf.shape[0] > self.batch_size:  # final ragged batch, padded
            per = buf.shape[0] // self.batch_size
            if per >= 2:
                block = buf[: per * self.batch_size].reshape(self.batch_size, per)
                tokens = np.full((self.batch_size, self.seq_len), self.pad_id, np.int32)
                labels = np.full((self.batch_size, self.seq_len), self.pad_id, np.int32)
                mask = np.zeros((self.batch_size, self.seq_len), np.float32)
                n = min(per - 1, self.seq_len)
                tokens[:, :n] = block[:, :n]
                labels[:, :n] = block[:, 1: n + 1]
                mask[:, :n] = 1.0
                yield {"tokens": tokens, "labels": labels, "mask": mask}


class SimilaritySampler:
    """Draw shard ids with probabilities proportional to similarity to a
    target prompt (EmApprox index reused for training-data curriculum)."""

    def __init__(self, probabilities: np.ndarray, seed: int = 0):
        p = np.asarray(probabilities, np.float64)
        if p.ndim != 1 or (p < 0).any():
            raise ValueError("probabilities must be a non-negative 1-D array")
        self.p = p / p.sum()
        self.rng = np.random.default_rng(seed)

    def draw_epoch_order(self, n_draws: Optional[int] = None) -> np.ndarray:
        n = n_draws or self.p.shape[0]
        return self.rng.choice(self.p.shape[0], size=n, replace=True, p=self.p)


class PrefetchIterator:
    """Background-thread prefetch so host batch assembly overlaps device
    compute (the CPU-side piece of compute/comm overlap)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self._sentinel = object()
        self._err: Optional[BaseException] = None

        def worker():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # propagate into consumer
                self._err = e
            finally:
                self._q.put(self._sentinel)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._sentinel:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
