"""Data substrate: synthetic corpora, tokenizer, sharded document store,
and the LM batch pipeline.

The sharded store is the TPU-native analogue of the paper's HDFS-block
layout: a shard (fixed token budget, rectangular arrays) is the cluster
sampling unit, the unit of data placement and the unit of fault recovery.
"""
from repro.data.corpus import (  # noqa: F401
    SyntheticCorpusConfig,
    generate_text_corpus,
    generate_review_corpus,
)
from repro.data.store import Document, DocShard, ShardedCorpus  # noqa: F401
from repro.data.tokenizer import HashTokenizer, Vocab  # noqa: F401
from repro.data.pipeline import LMBatchPipeline, SimilaritySampler  # noqa: F401
