"""Sharded document store — the HDFS-block analogue.

Documents are stored CSR-style per shard: a flat int32 token array plus
an int64 offsets array.  A shard is the cluster-sampling unit (paper
Sec. II-B) and the unit of placement on the ``data`` mesh axis.

The store supports *reallocation*: given a document→shard assignment
(e.g. from spherical k-means, paper Sec. IV-D) it rebuilds shards so
semantically similar documents are co-located.

Postings (query-side acceleration): each shard lazily builds a CSR
postings cache ``word -> (local doc index, term frequency)`` on first
use (``shard_postings``).  Word-driven operators — BM25 scoring,
Boolean document matching — then walk only the postings of the query
words, O(matching tokens), instead of rescanning the full flat token
array once per (query, word) pair, O(shard_tokens x query_words).  The
trade-off: the one-time build costs one sort of the shard's tokens and
~8 bytes per distinct (word, doc) pair, which pays for itself after a
couple of queries touching the shard; the flat-scan implementations are
kept (``*_scan``) as parity references and for one-shot scans where
building the cache would be wasted work.

Persistence: ``ShardedCorpus.save``/``load`` round-trip the per-shard
CSR payload *and* the postings next to it, so a cold serving process
opens the corpus with every shard's inverted index already attached —
no one-time rebuild on the first query to touch each shard.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Iterator, List, Sequence

import numpy as np


def atomic_savez(path: str, **payload: np.ndarray) -> None:
    """Write a compressed npz atomically: savez into a tempfile in the
    target directory, then ``os.replace`` over ``path`` — readers never
    see a half-written file.  (np.savez appends ``.npz`` to suffixless
    names, hence the existence probe.)  Shared by every on-disk artifact
    (corpus + postings here, the index in core/index.py)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        np.savez_compressed(tmp, **payload)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp,
                   path)
    finally:
        for leftover in (tmp, tmp + ".npz"):
            if os.path.exists(leftover):
                os.unlink(leftover)


@dataclasses.dataclass(frozen=True)
class Document:
    """A single document: token ids plus a stable global id."""
    doc_id: int
    tokens: np.ndarray  # int32 [len]

    def __len__(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class DocShard:
    """One subcollection of documents (CSR layout)."""
    shard_id: int
    tokens: np.ndarray       # int32 [total_tokens_in_shard]
    offsets: np.ndarray      # int64 [n_docs + 1]
    doc_ids: np.ndarray      # int64 [n_docs] global document ids

    @property
    def n_docs(self) -> int:
        return int(self.doc_ids.shape[0])

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])

    def document(self, i: int) -> Document:
        lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
        return Document(int(self.doc_ids[i]), self.tokens[lo:hi])

    def iter_documents(self) -> Iterator[Document]:
        for i in range(self.n_docs):
            yield self.document(i)

    @staticmethod
    def from_documents(shard_id: int, docs: Sequence[Document]) -> "DocShard":
        if docs:
            tokens = np.concatenate([d.tokens for d in docs]).astype(np.int32)
            offsets = np.zeros(len(docs) + 1, np.int64)
            np.cumsum([len(d) for d in docs], out=offsets[1:])
            doc_ids = np.asarray([d.doc_id for d in docs], np.int64)
        else:
            tokens = np.zeros((0,), np.int32)
            offsets = np.zeros((1,), np.int64)
            doc_ids = np.zeros((0,), np.int64)
        return DocShard(shard_id, tokens, offsets, doc_ids)


class ShardedCorpus:
    """A corpus partitioned into shards (subcollections).

    ``shard_tokens`` is the target token budget per shard — the analogue
    of the paper's 32 MB HDFS block size.
    """

    def __init__(self, shards: List[DocShard], vocab_size: int):
        self.shards = shards
        self.vocab_size = int(vocab_size)
        self._doc_to_shard = None

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def from_documents(
        docs: Sequence[Document],
        vocab_size: int,
        shard_tokens: int = 1 << 18,
    ) -> "ShardedCorpus":
        """Sequential allocation: fill shards to the token budget in doc
        order (the 'as-ingested' layout, before k-means reallocation)."""
        shards: List[DocShard] = []
        cur: List[Document] = []
        cur_tokens = 0
        for d in docs:
            cur.append(d)
            cur_tokens += len(d)
            if cur_tokens >= shard_tokens:
                shards.append(DocShard.from_documents(len(shards), cur))
                cur, cur_tokens = [], 0
        if cur:
            shards.append(DocShard.from_documents(len(shards), cur))
        return ShardedCorpus(shards, vocab_size)

    def reallocate(self, assignment: np.ndarray, n_shards: int) -> "ShardedCorpus":
        """Rebuild shards from a document→shard assignment vector indexed
        by global doc_id (paper Sec. IV-D: cluster-based allocation)."""
        buckets: List[List[Document]] = [[] for _ in range(n_shards)]
        for shard in self.shards:
            for doc in shard.iter_documents():
                buckets[int(assignment[doc.doc_id])].append(doc)
        shards = [DocShard.from_documents(i, b) for i, b in enumerate(buckets)]
        return ShardedCorpus(shards, self.vocab_size)

    def append_documents(
        self,
        docs_tokens: Sequence[np.ndarray],
        *,
        shard_tokens: "int | None" = None,
    ) -> "tuple[ShardedCorpus, np.ndarray, List[int]]":
        """Live-ingest append path: stream new documents into the open
        (last) shard, copy-on-write.

        Returns ``(new_corpus, new_doc_ids, affected_shard_ids)``.  The
        new corpus *shares every untouched shard object by reference* —
        only the grown open shard (and any spill shards) are new — so
        readers holding the old corpus keep an immutable view
        (RCU-style: the ingestor swaps the corpus reference, it never
        mutates one in place).  Appended docs take dense global ids
        starting at ``self.n_docs`` (``doc_shard_map`` requires dense
        ids).  With ``shard_tokens`` set, the open shard fills to the
        same token budget as ``from_documents`` (the crossing doc is
        appended, then the shard closes) and the remainder spills into
        new shards; ``None`` grows the open shard unboundedly — the
        no-new-shards mode, where placement never needs to change.

        A grown shard whose source had CSR postings built gets them
        *delta-merged* (``merge_postings``) instead of rebuilt: the
        appended docs' local indices all sort after the existing ones
        within every word row, so the merged postings are bit-for-bit
        what a from-scratch ``build_postings`` of the grown shard
        produces (pinned by tests) at the cost of indexing only the
        delta."""
        if not len(docs_tokens):
            return self, np.zeros(0, np.int64), []
        base = self.n_docs
        docs = [Document(base + i, np.asarray(t, np.int32))
                for i, t in enumerate(docs_tokens)]
        budget = None if shard_tokens is None else int(shard_tokens)
        shards = list(self.shards)
        affected: List[int] = []
        queue = list(docs)
        if shards and (budget is None or shards[-1].n_tokens < budget):
            open_shard = shards[-1]
            take: List[Document] = []
            cur = open_shard.n_tokens
            while queue and (budget is None or cur < budget):
                d = queue.pop(0)
                take.append(d)
                cur += len(d)
            if take:
                shards[-1] = _append_to_shard(open_shard, take)
                affected.append(open_shard.shard_id)
        while queue:
            group: List[Document] = []
            cur = 0
            while queue and (budget is None or cur < budget):
                d = queue.pop(0)
                group.append(d)
                cur += len(d)
            sid = len(shards)
            shards.append(DocShard.from_documents(sid, group))
            affected.append(sid)
        new_ids = np.arange(base, base + len(docs), dtype=np.int64)
        return ShardedCorpus(shards, self.vocab_size), new_ids, affected

    # ------------------------------------------------------------------
    # inspection
    # ------------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_docs(self) -> int:
        return sum(s.n_docs for s in self.shards)

    @property
    def n_tokens(self) -> int:
        return sum(s.n_tokens for s in self.shards)

    def iter_documents(self) -> Iterator[Document]:
        for s in self.shards:
            yield from s.iter_documents()

    def doc_shard_map(self) -> np.ndarray:
        """Global doc_id → shard_id (cached)."""
        if self._doc_to_shard is None:
            out = np.full(self.n_docs, -1, np.int64)
            for s in self.shards:
                out[s.doc_ids] = s.shard_id
            self._doc_to_shard = out
        return self._doc_to_shard

    def shard_doc_counts(self) -> np.ndarray:
        return np.asarray([s.n_docs for s in self.shards], np.int64)

    def shard_token_counts(self) -> np.ndarray:
        return np.asarray([s.n_tokens for s in self.shards], np.int64)

    # ------------------------------------------------------------------
    # exact counting oracles (used by tests and precise execution)
    # ------------------------------------------------------------------
    def count_phrase(self, phrase: Sequence[int]) -> int:
        """Exact number of occurrences of ``phrase`` in the corpus."""
        return sum(count_phrase_in_shard(s, phrase) for s in self.shards)

    # ------------------------------------------------------------------
    # persistence (atomic; shard payload + CSR postings side by side)
    # ------------------------------------------------------------------
    def save(self, path: str, *, include_postings: bool = True) -> None:
        """Write the corpus to one compressed npz.

        ``include_postings=True`` (default) persists each shard's CSR
        postings next to its token payload — building any that were not
        built yet — so a process that ``load``s the file serves its
        first queries without paying the one-time postings rebuild.
        Set False to store the raw payload only (smaller file, lazy
        rebuild on first use as before)."""
        payload = dict(meta=np.asarray(json.dumps(dict(
            vocab_size=self.vocab_size, n_shards=self.n_shards,
            postings=bool(include_postings)))))
        for i, shard in enumerate(self.shards):
            payload[f"s{i}_tokens"] = shard.tokens
            payload[f"s{i}_offsets"] = shard.offsets
            payload[f"s{i}_doc_ids"] = shard.doc_ids
            if include_postings:
                post = shard_postings(shard)
                payload[f"s{i}_indptr"] = post.indptr
                payload[f"s{i}_doc_idx"] = post.doc_idx
                payload[f"s{i}_tf"] = post.tf
        atomic_savez(path, **payload)

    @staticmethod
    def load(path: str) -> "ShardedCorpus":
        """Open a saved corpus; persisted postings are re-attached to
        their shards, so ``shard_postings`` is a cache hit from the
        first query onward (cold processes skip the rebuild)."""
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["meta"]))
        shards: List[DocShard] = []
        for i in range(int(meta["n_shards"])):
            shard = DocShard(i, z[f"s{i}_tokens"], z[f"s{i}_offsets"],
                             z[f"s{i}_doc_ids"])
            if meta.get("postings"):
                shard._postings = ShardPostings(
                    z[f"s{i}_indptr"], z[f"s{i}_doc_idx"], z[f"s{i}_tf"])
            shards.append(shard)
        return ShardedCorpus(shards, int(meta["vocab_size"]))


def count_phrase_in_shard(shard: DocShard, phrase: Sequence[int]) -> int:
    """Occurrences of a token n-gram within a shard, never crossing
    document boundaries."""
    phrase = np.asarray(phrase, np.int32)
    k = len(phrase)
    if k == 0 or shard.n_tokens < k:
        return 0
    tokens = shard.tokens
    if k == 1:
        return int(np.count_nonzero(tokens == phrase[0]))
    # vectorized n-gram match over the flat array
    match = tokens[: len(tokens) - k + 1] == phrase[0]
    for j in range(1, k):
        match &= tokens[j: len(tokens) - k + 1 + j] == phrase[j]
    if not match.any():
        return 0
    # kill matches that straddle a document boundary
    pos = np.nonzero(match)[0]
    doc_of_start = np.searchsorted(shard.offsets, pos, side="right") - 1
    doc_of_end = np.searchsorted(shard.offsets, pos + k - 1, side="right") - 1
    return int(np.count_nonzero(doc_of_start == doc_of_end))


def segment_sum_by_offsets(values: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """Per-document sums over a CSR layout.  Handles empty documents
    anywhere: np.add.reduceat alone mis-handles empty segments (and
    raises on out-of-bounds starts), so it runs only at the starts of
    non-empty documents — strictly increasing, in-bounds slices — and
    the empty documents stay zero.  (Clamping empty starts into range
    instead would split the last tokens of the preceding document into
    the wrong slice whenever an empty doc sits at the end.)"""
    n_docs = len(offsets) - 1
    out = np.zeros(n_docs, values.dtype)
    if n_docs == 0 or values.shape[0] == 0:
        return out
    lens = np.diff(offsets)
    nonempty = lens > 0
    if nonempty.any():
        out[nonempty] = np.add.reduceat(values, offsets[:-1][nonempty])
    return out


def plan_blocked_layout(counts: Sequence[int], block: int
                        ) -> "tuple[np.ndarray, np.ndarray, int]":
    """Row layout for a block-aligned packed multi-segment payload
    (the megascan's input contract, kernels/megascan): each segment's
    rows are padded *independently* up to a multiple of ``block`` before
    concatenation, so every ``block``-row slab belongs to exactly one
    segment.  Returns ``(row_starts, blocks, total_rows)``: segment
    ``i``'s real rows occupy ``[row_starts[i], row_starts[i] +
    counts[i])``, it owns ``blocks[i]`` slabs, and the packed array has
    ``total_rows`` rows in all.  Empty segments get zero slabs (they
    occupy no rows at all, not an empty padded slab)."""
    counts = np.asarray(counts, np.int64)
    if block <= 0:
        raise ValueError(f"block size must be positive, got {block}")
    if (counts < 0).any():
        raise ValueError("segment counts must be non-negative")
    blocks = -(-counts // block)
    row_starts = np.zeros(counts.shape[0], np.int64)
    if counts.shape[0] > 1:
        np.cumsum(blocks[:-1] * block, out=row_starts[1:])
    return row_starts, blocks, int(blocks.sum() * block)


def docs_matching_all(shard: DocShard, words: Sequence[int]) -> np.ndarray:
    """Global doc_ids in ``shard`` containing *all* of ``words``
    (postings-driven; see ``docs_matching_all_scan`` for the flat-scan
    parity reference)."""
    post = shard_postings(shard)
    ok = np.ones(shard.n_docs, bool)
    for w in words:
        m = np.zeros(shard.n_docs, bool)
        m[post.lookup(w)[0]] = True
        ok &= m
    return shard.doc_ids[ok]


def docs_matching_all_scan(shard: DocShard, words: Sequence[int]) -> np.ndarray:
    """Flat-scan reference for ``docs_matching_all`` — O(shard tokens)
    per word."""
    ok = np.ones(shard.n_docs, bool)
    for w in words:
        hit = (shard.tokens == np.int32(w)).astype(np.int64)
        ok &= segment_sum_by_offsets(hit, shard.offsets) > 0
    return shard.doc_ids[ok]


# ----------------------------------------------------------------------
# per-shard CSR postings (lazily built, cached on the shard)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardPostings:
    """CSR inverted index for one shard: row = word id, entries =
    (local document index, term frequency).

    ``indptr`` is [vocab_local + 1] with vocab_local = max token + 1 —
    lookups of words the shard never saw fall off the end and return
    empty slices, so callers never need the global vocab size.
    """
    indptr: np.ndarray    # int64 [vocab_local + 1]
    doc_idx: np.ndarray   # int32 [nnz] local doc index within the shard
    tf: np.ndarray        # int32 [nnz] term frequency

    def lookup(self, word: int) -> "tuple[np.ndarray, np.ndarray]":
        """(local doc indices, term frequencies) for ``word``."""
        w = int(word)
        if w < 0 or w >= self.indptr.shape[0] - 1:
            return (np.zeros(0, np.int32), np.zeros(0, np.int32))
        lo, hi = int(self.indptr[w]), int(self.indptr[w + 1])
        return (self.doc_idx[lo:hi], self.tf[lo:hi])

    def word_count(self, word: int) -> int:
        """Total occurrences of ``word`` in the shard (sum of tf)."""
        return int(self.lookup(word)[1].sum())

    @property
    def nbytes(self) -> int:
        return self.indptr.nbytes + self.doc_idx.nbytes + self.tf.nbytes


def build_postings(shard: DocShard) -> ShardPostings:
    """One pass over the shard's CSR token array: key each token by
    (word, doc), count distinct keys, and lay the pairs out word-major
    (np.unique returns keys sorted, and word is the high digit)."""
    n_docs = shard.n_docs
    if n_docs == 0 or shard.n_tokens == 0:
        z32 = np.zeros(0, np.int32)
        return ShardPostings(np.zeros(1, np.int64), z32, z32)
    doc_of = np.repeat(np.arange(n_docs, dtype=np.int64),
                       np.diff(shard.offsets))
    key = shard.tokens.astype(np.int64) * n_docs + doc_of
    uniq, tf = np.unique(key, return_counts=True)
    words = uniq // n_docs
    vocab_local = int(shard.tokens.max()) + 1
    indptr = np.zeros(vocab_local + 1, np.int64)
    np.cumsum(np.bincount(words, minlength=vocab_local), out=indptr[1:])
    return ShardPostings(indptr, (uniq % n_docs).astype(np.int32),
                         tf.astype(np.int32))


def shard_postings(shard: DocShard) -> ShardPostings:
    """Postings for ``shard``, built lazily and cached on the shard
    object.  Concurrent first calls may both build (benign — identical
    results, last write wins); afterwards every query touching the
    shard reuses the cache, which is what makes the batched engine's
    shared scans cheap."""
    post = getattr(shard, "_postings", None)
    if post is None:
        post = build_postings(shard)
        shard._postings = post
    return post


def merge_postings(old: ShardPostings, old_n_docs: int,
                   delta: ShardPostings) -> ShardPostings:
    """CSR segment append: merge a shard's existing postings with the
    postings of its appended-docs delta (local doc indices 0..k-1 in
    ``delta``, shifted up by ``old_n_docs`` here).

    Bit-for-bit equal to ``build_postings`` on the grown shard: within
    every word row ``build_postings`` orders entries by ascending local
    doc index (np.unique on word-major keys), and every appended doc's
    index is >= ``old_n_docs`` > every existing one — so the rebuilt
    row is exactly (old entries, then shifted delta entries).  The
    existing arrays are never copied element-by-element through Python:
    both sides scatter into the merged layout with vectorized position
    arithmetic."""
    vocab = max(old.indptr.shape[0], delta.indptr.shape[0]) - 1

    def row_counts(p: ShardPostings) -> np.ndarray:
        c = np.zeros(vocab, np.int64)
        c[: p.indptr.shape[0] - 1] = np.diff(p.indptr)
        return c

    c_old, c_delta = row_counts(old), row_counts(delta)
    indptr = np.zeros(vocab + 1, np.int64)
    np.cumsum(c_old + c_delta, out=indptr[1:])
    doc_idx = np.empty(int(indptr[-1]), np.int32)
    tf = np.empty(int(indptr[-1]), np.int32)
    if old.doc_idx.shape[0]:
        w = np.repeat(np.arange(vocab, dtype=np.int64), c_old)
        pos = indptr[w] + (np.arange(old.doc_idx.shape[0]) - old.indptr[w])
        doc_idx[pos] = old.doc_idx
        tf[pos] = old.tf
    if delta.doc_idx.shape[0]:
        w = np.repeat(np.arange(vocab, dtype=np.int64), c_delta)
        pos = (indptr[w] + c_old[w]
               + (np.arange(delta.doc_idx.shape[0]) - delta.indptr[w]))
        doc_idx[pos] = (delta.doc_idx.astype(np.int64)
                        + old_n_docs).astype(np.int32)
        tf[pos] = delta.tf
    return ShardPostings(indptr, doc_idx, tf)


def _append_to_shard(shard: DocShard, docs: Sequence[Document]) -> DocShard:
    """A NEW shard object = ``shard`` + ``docs`` appended (the source
    shard is never mutated — old-generation readers keep scanning it).
    If the source had postings built, the grown shard gets them
    delta-merged rather than rebuilt."""
    tokens = np.concatenate(
        [shard.tokens] + [d.tokens for d in docs]).astype(np.int32)
    lens = np.asarray([len(d) for d in docs], np.int64)
    offsets = np.concatenate(
        [shard.offsets, shard.offsets[-1] + np.cumsum(lens)])
    doc_ids = np.concatenate(
        [shard.doc_ids, np.asarray([d.doc_id for d in docs], np.int64)])
    grown = DocShard(shard.shard_id, tokens, offsets, doc_ids)
    old_post = getattr(shard, "_postings", None)
    if old_post is not None:
        delta = DocShard.from_documents(
            shard.shard_id,
            [Document(i, d.tokens) for i, d in enumerate(docs)])
        grown._postings = merge_postings(old_post, shard.n_docs,
                                         build_postings(delta))
    return grown
