"""Sharded document store — the HDFS-block analogue.

Documents are stored CSR-style per shard: a flat int32 token array plus
an int64 offsets array.  A shard is the cluster-sampling unit (paper
Sec. II-B) and the unit of placement on the ``data`` mesh axis.

The store supports *reallocation*: given a document→shard assignment
(e.g. from spherical k-means, paper Sec. IV-D) it rebuilds shards so
semantically similar documents are co-located.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Document:
    """A single document: token ids plus a stable global id."""
    doc_id: int
    tokens: np.ndarray  # int32 [len]

    def __len__(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class DocShard:
    """One subcollection of documents (CSR layout)."""
    shard_id: int
    tokens: np.ndarray       # int32 [total_tokens_in_shard]
    offsets: np.ndarray      # int64 [n_docs + 1]
    doc_ids: np.ndarray      # int64 [n_docs] global document ids

    @property
    def n_docs(self) -> int:
        return int(self.doc_ids.shape[0])

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])

    def document(self, i: int) -> Document:
        lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
        return Document(int(self.doc_ids[i]), self.tokens[lo:hi])

    def iter_documents(self) -> Iterator[Document]:
        for i in range(self.n_docs):
            yield self.document(i)

    @staticmethod
    def from_documents(shard_id: int, docs: Sequence[Document]) -> "DocShard":
        if docs:
            tokens = np.concatenate([d.tokens for d in docs]).astype(np.int32)
            offsets = np.zeros(len(docs) + 1, np.int64)
            np.cumsum([len(d) for d in docs], out=offsets[1:])
            doc_ids = np.asarray([d.doc_id for d in docs], np.int64)
        else:
            tokens = np.zeros((0,), np.int32)
            offsets = np.zeros((1,), np.int64)
            doc_ids = np.zeros((0,), np.int64)
        return DocShard(shard_id, tokens, offsets, doc_ids)


class ShardedCorpus:
    """A corpus partitioned into shards (subcollections).

    ``shard_tokens`` is the target token budget per shard — the analogue
    of the paper's 32 MB HDFS block size.
    """

    def __init__(self, shards: List[DocShard], vocab_size: int):
        self.shards = shards
        self.vocab_size = int(vocab_size)
        self._doc_to_shard = None

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def from_documents(
        docs: Sequence[Document],
        vocab_size: int,
        shard_tokens: int = 1 << 18,
    ) -> "ShardedCorpus":
        """Sequential allocation: fill shards to the token budget in doc
        order (the 'as-ingested' layout, before k-means reallocation)."""
        shards: List[DocShard] = []
        cur: List[Document] = []
        cur_tokens = 0
        for d in docs:
            cur.append(d)
            cur_tokens += len(d)
            if cur_tokens >= shard_tokens:
                shards.append(DocShard.from_documents(len(shards), cur))
                cur, cur_tokens = [], 0
        if cur:
            shards.append(DocShard.from_documents(len(shards), cur))
        return ShardedCorpus(shards, vocab_size)

    def reallocate(self, assignment: np.ndarray, n_shards: int) -> "ShardedCorpus":
        """Rebuild shards from a document→shard assignment vector indexed
        by global doc_id (paper Sec. IV-D: cluster-based allocation)."""
        buckets: List[List[Document]] = [[] for _ in range(n_shards)]
        for shard in self.shards:
            for doc in shard.iter_documents():
                buckets[int(assignment[doc.doc_id])].append(doc)
        shards = [DocShard.from_documents(i, b) for i, b in enumerate(buckets)]
        return ShardedCorpus(shards, self.vocab_size)

    # ------------------------------------------------------------------
    # inspection
    # ------------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def n_docs(self) -> int:
        return sum(s.n_docs for s in self.shards)

    @property
    def n_tokens(self) -> int:
        return sum(s.n_tokens for s in self.shards)

    def iter_documents(self) -> Iterator[Document]:
        for s in self.shards:
            yield from s.iter_documents()

    def doc_shard_map(self) -> np.ndarray:
        """Global doc_id → shard_id (cached)."""
        if self._doc_to_shard is None:
            out = np.full(self.n_docs, -1, np.int64)
            for s in self.shards:
                out[s.doc_ids] = s.shard_id
            self._doc_to_shard = out
        return self._doc_to_shard

    def shard_doc_counts(self) -> np.ndarray:
        return np.asarray([s.n_docs for s in self.shards], np.int64)

    def shard_token_counts(self) -> np.ndarray:
        return np.asarray([s.n_tokens for s in self.shards], np.int64)

    # ------------------------------------------------------------------
    # exact counting oracles (used by tests and precise execution)
    # ------------------------------------------------------------------
    def count_phrase(self, phrase: Sequence[int]) -> int:
        """Exact number of occurrences of ``phrase`` in the corpus."""
        return sum(count_phrase_in_shard(s, phrase) for s in self.shards)


def count_phrase_in_shard(shard: DocShard, phrase: Sequence[int]) -> int:
    """Occurrences of a token n-gram within a shard, never crossing
    document boundaries."""
    phrase = np.asarray(phrase, np.int32)
    k = len(phrase)
    if k == 0 or shard.n_tokens < k:
        return 0
    tokens = shard.tokens
    if k == 1:
        return int(np.count_nonzero(tokens == phrase[0]))
    # vectorized n-gram match over the flat array
    match = tokens[: len(tokens) - k + 1] == phrase[0]
    for j in range(1, k):
        match &= tokens[j: len(tokens) - k + 1 + j] == phrase[j]
    if not match.any():
        return 0
    # kill matches that straddle a document boundary
    pos = np.nonzero(match)[0]
    doc_of_start = np.searchsorted(shard.offsets, pos, side="right") - 1
    doc_of_end = np.searchsorted(shard.offsets, pos + k - 1, side="right") - 1
    return int(np.count_nonzero(doc_of_start == doc_of_end))


def segment_sum_by_offsets(values: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """Per-document sums over a CSR layout.  Handles empty documents
    anywhere (np.add.reduceat alone mis-handles empty segments and
    raises when an empty doc sits at the end)."""
    n_docs = len(offsets) - 1
    if n_docs == 0:
        return np.zeros(0, values.dtype)
    total = values.shape[0]
    starts = np.minimum(offsets[:-1], max(total - 1, 0))
    if total == 0:
        return np.zeros(n_docs, values.dtype)
    seg = np.add.reduceat(values, starts)
    lens = np.diff(offsets)
    return np.where(lens > 0, seg, 0)


def docs_matching_all(shard: DocShard, words: Sequence[int]) -> np.ndarray:
    """Global doc_ids in ``shard`` containing *all* of ``words``."""
    ok = np.ones(shard.n_docs, bool)
    for w in words:
        hit = (shard.tokens == np.int32(w)).astype(np.int64)
        ok &= segment_sum_by_offsets(hit, shard.offsets) > 0
    return shard.doc_ids[ok]
