"""Minimal tokenizer layer.

The synthetic corpora generate token ids directly; this module exists so
the examples can also ingest real text files.  ``Vocab`` maps strings to
contiguous ids; ``HashTokenizer`` is an open-vocabulary fallback that
buckets unseen words (the paper assumes queries stay in-vocabulary,
Sec. V — we keep that assumption for query words but not for corpus
ingestion).
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Optional

_WORD_RE = re.compile(r"[a-z0-9']+")


def simple_word_split(text: str) -> List[str]:
    return _WORD_RE.findall(text.lower())


class Vocab:
    def __init__(self, words: Optional[Iterable[str]] = None):
        self._w2i: Dict[str, int] = {}
        self._i2w: List[str] = []
        if words:
            for w in words:
                self.add(w)

    def add(self, word: str) -> int:
        idx = self._w2i.get(word)
        if idx is None:
            idx = len(self._i2w)
            self._w2i[word] = idx
            self._i2w.append(word)
        return idx

    def __len__(self) -> int:
        return len(self._i2w)

    def __contains__(self, word: str) -> bool:
        return word in self._w2i

    def id(self, word: str) -> int:
        return self._w2i[word]

    def word(self, idx: int) -> str:
        return self._i2w[idx]

    @staticmethod
    def build(texts: Iterable[str], max_size: int = 1 << 17) -> "Vocab":
        from collections import Counter
        counts: Counter = Counter()
        for t in texts:
            counts.update(simple_word_split(t))
        vocab = Vocab()
        for w, _ in counts.most_common(max_size):
            vocab.add(w)
        return vocab


class HashTokenizer:
    """Tokenize with a closed vocab; hash OOV words into reserved buckets."""

    def __init__(self, vocab: Vocab, oov_buckets: int = 1024):
        self.vocab = vocab
        self.oov_buckets = oov_buckets

    @property
    def vocab_size(self) -> int:
        return len(self.vocab) + self.oov_buckets

    def encode(self, text: str):
        import numpy as np
        ids = []
        base = len(self.vocab)
        for w in simple_word_split(text):
            if w in self.vocab:
                ids.append(self.vocab.id(w))
            else:
                ids.append(base + (hash(w) % self.oov_buckets))
        return np.asarray(ids, np.int32)
