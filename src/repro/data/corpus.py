"""Synthetic corpora with controlled skew.

The paper evaluates on Wikipedia / CCNews / Amazon reviews.  Offline we
reproduce their *statistical shape* rather than their bytes: a topic
mixture model with Zipfian within-topic word distributions.  Documents
drawn from few topics + Zipf word laws give exactly the skewed
phrase-occurrence distributions that make similarity-driven sampling
beat random sampling (paper Sec. I: "random sampling can lead to large
errors ... when sampling from a skewed distribution").

Two generators:
  * ``generate_text_corpus``   -> Wikipedia/CCNews analogue.
  * ``generate_review_corpus`` -> Amazon analogue (users x items x
    ratings, review text correlated with user preference vectors) for
    the recommendation queries.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.data.store import Document


@dataclasses.dataclass(frozen=True)
class SyntheticCorpusConfig:
    vocab_size: int = 8192
    n_topics: int = 24
    n_docs: int = 4096
    mean_doc_len: int = 160
    std_doc_len: int = 60
    min_doc_len: int = 16
    zipf_exponent: float = 1.07
    # concentration of a document's topic mixture; smaller = more skew
    doc_topic_alpha: float = 0.08
    # Order documents by dominant topic (with noise). Real corpora have
    # strong arrival locality — Wikipedia dumps are category-clustered,
    # Common Crawl visits sites consecutively — which is what gives HDFS
    # blocks their natural skew (paper Sec. I).  0.0 = random order,
    # 1.0 = perfectly topic-sorted.
    topic_locality: float = 0.85
    seed: int = 0


def _topic_word_dists(cfg: SyntheticCorpusConfig, rng: np.random.Generator) -> np.ndarray:
    """[n_topics, vocab] rows: 30% of each topic's mass is a shared
    Zipf law over the whole vocabulary (stopword-like words common to
    every topic) and 70% is a Zipf law over a topic-EXCLUSIVE slice of
    the vocabulary.  Topic-exclusive heads are what give real corpora
    their per-block skew ("Yankees" lives in sports pages); a plain
    per-topic permutation spreads every mid-frequency word across many
    topics and kills the skew the paper's sampling exploits."""
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    shared = ranks ** (-cfg.zipf_exponent)
    shared /= shared.sum()
    shared = shared[rng.permutation(cfg.vocab_size)]

    block = cfg.vocab_size // (cfg.n_topics + 1)   # last block: shared-only
    dists = np.empty((cfg.n_topics, cfg.vocab_size), np.float64)
    for t in range(cfg.n_topics):
        own = np.zeros(cfg.vocab_size, np.float64)
        lo, hi = t * block, (t + 1) * block
        local_ranks = np.arange(1, hi - lo + 1, dtype=np.float64)
        own_p = local_ranks ** (-cfg.zipf_exponent)
        own[lo + rng.permutation(hi - lo)] = own_p / own_p.sum()
        dists[t] = 0.3 * shared + 0.7 * own
    return dists


def generate_text_corpus(
    cfg: SyntheticCorpusConfig,
) -> Tuple[List[Document], np.ndarray]:
    """Returns (documents, doc_topic_weights[n_docs, n_topics])."""
    rng = np.random.default_rng(cfg.seed)
    topic_dists = _topic_word_dists(cfg, rng)
    doc_topics = rng.dirichlet(
        np.full(cfg.n_topics, cfg.doc_topic_alpha), size=cfg.n_docs
    )
    lengths = np.clip(
        rng.normal(cfg.mean_doc_len, cfg.std_doc_len, cfg.n_docs).astype(np.int64),
        cfg.min_doc_len,
        None,
    )
    # Pre-draw word pools per topic (vectorized): each topic gets a large
    # reservoir sampled from its Zipf law; documents then slice from the
    # reservoirs according to their per-word topic assignments.
    total = int(lengths.sum())
    # per-word topic assignment for the whole corpus at once
    doc_index = np.repeat(np.arange(cfg.n_docs), lengths)
    u = rng.random(total)
    cum = np.cumsum(doc_topics, axis=1)
    word_topic = (u[:, None] > cum[doc_index]).sum(axis=1)
    tokens = np.empty(total, np.int32)
    for t in range(cfg.n_topics):
        mask = word_topic == t
        n = int(mask.sum())
        if n:
            tokens[mask] = rng.choice(cfg.vocab_size, size=n, p=topic_dists[t]).astype(np.int32)
    offsets = np.zeros(cfg.n_docs + 1, np.int64)
    np.cumsum(lengths, out=offsets[1:])

    # arrival-order locality: sort by dominant topic + noise
    if cfg.topic_locality > 0:
        dominant = doc_topics.argmax(axis=1).astype(np.float64)
        noise = rng.normal(0, (1.0 - cfg.topic_locality) * cfg.n_topics + 1e-9,
                           cfg.n_docs)
        order = np.argsort(dominant + noise, kind="stable")
    else:
        order = np.arange(cfg.n_docs)

    docs: List[Document] = []
    for new_id, i in enumerate(order):
        docs.append(Document(new_id, tokens[offsets[i]: offsets[i + 1]]))
    return docs, doc_topics[order]


@dataclasses.dataclass(frozen=True)
class ReviewCorpusConfig:
    vocab_size: int = 8192
    n_topics: int = 16
    n_users: int = 512
    n_items: int = 256
    reviews_per_user_mean: int = 20
    review_len_mean: int = 40
    zipf_exponent: float = 1.07
    rating_noise: float = 0.35
    seed: int = 1


@dataclasses.dataclass
class ReviewData:
    """Amazon-analogue interaction data.

    ``user_docs[u]`` concatenates all reviews written by user ``u`` — the
    paper's definition of a document for the recommendation workload
    (Table II: 'all reviews written by the same user').
    """
    user_docs: List[Document]
    ratings: np.ndarray          # float32 [n_interactions]
    user_of: np.ndarray          # int64   [n_interactions]
    item_of: np.ndarray          # int64   [n_interactions]
    user_topics: np.ndarray      # [n_users, n_topics] preference vectors
    item_topics: np.ndarray      # [n_items, n_topics]
    vocab_size: int = 0

    def ratings_matrix(self) -> np.ndarray:
        """Dense [n_users, n_items] matrix with NaN for missing."""
        n_u = self.user_topics.shape[0]
        n_i = self.item_topics.shape[0]
        m = np.full((n_u, n_i), np.nan, np.float32)
        m[self.user_of, self.item_of] = self.ratings
        return m


def generate_review_corpus(cfg: ReviewCorpusConfig) -> ReviewData:
    rng = np.random.default_rng(cfg.seed)
    word_cfg = SyntheticCorpusConfig(
        vocab_size=cfg.vocab_size, n_topics=cfg.n_topics,
        zipf_exponent=cfg.zipf_exponent, seed=cfg.seed,
    )
    topic_dists = _topic_word_dists(word_cfg, rng)
    user_topics = rng.dirichlet(np.full(cfg.n_topics, 0.15), size=cfg.n_users)
    item_topics = rng.dirichlet(np.full(cfg.n_topics, 0.15), size=cfg.n_items)

    # affinity -> rating on a 1..5 scale
    affinity = user_topics @ item_topics.T            # [U, I]
    a_min, a_max = affinity.min(), affinity.max()
    scaled = 1.0 + 4.0 * (affinity - a_min) / max(a_max - a_min, 1e-9)

    users, items, ratings = [], [], []
    user_tokens: List[List[np.ndarray]] = [[] for _ in range(cfg.n_users)]
    for u in range(cfg.n_users):
        k = max(2, int(rng.poisson(cfg.reviews_per_user_mean)))
        k = min(k, cfg.n_items)
        # users review items they're predisposed to encounter
        p = affinity[u] / affinity[u].sum()
        chosen = rng.choice(cfg.n_items, size=k, replace=False, p=p)
        for i in chosen:
            r = np.clip(scaled[u, i] + rng.normal(0, cfg.rating_noise), 1.0, 5.0)
            users.append(u)
            items.append(int(i))
            ratings.append(float(r))
            # review text: mixture of user and item topics
            mix = 0.5 * user_topics[u] + 0.5 * item_topics[i]
            length = max(8, int(rng.normal(cfg.review_len_mean, cfg.review_len_mean / 3)))
            wt = rng.choice(cfg.n_topics, size=length, p=mix)
            toks = np.empty(length, np.int32)
            for t in np.unique(wt):
                m = wt == t
                toks[m] = rng.choice(cfg.vocab_size, size=int(m.sum()), p=topic_dists[t]).astype(np.int32)
            user_tokens[u].append(toks)

    user_docs = [
        Document(u, np.concatenate(user_tokens[u]) if user_tokens[u] else np.zeros(0, np.int32))
        for u in range(cfg.n_users)
    ]
    return ReviewData(
        user_docs=user_docs,
        ratings=np.asarray(ratings, np.float32),
        user_of=np.asarray(users, np.int64),
        item_of=np.asarray(items, np.int64),
        user_topics=user_topics,
        item_topics=item_topics,
        vocab_size=cfg.vocab_size,
    )
