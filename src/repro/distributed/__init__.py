"""Distribution layer: mesh axes, logical sharding rules, collective
helpers, and gradient compression."""
from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES,
    logical_to_mesh_spec,
    shard_constraint,
    set_rules,
    get_rules,
)
