"""Gradient compression for the cross-pod all-reduce.

At 1000+ nodes the gradient all-reduce across pods rides the slow
inter-pod links (DCN), not ICI.  We compress that hop only: gradients
are reduced *within* a pod at full precision (ICI is fast), then the
cross-pod exchange runs on int8 blockwise-quantized tensors with error
feedback (the residual from quantization is added to the next step's
gradient, which keeps SGD convergence — Karimireddy et al. 2019).

Usage inside a shard_map'd step:
    g_pod  = jax.lax.psum(g, "data")                  # fast intra-pod
    g_all, new_err = compressed_cross_pod_sum(g_pod, err, "pod")
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.optimizer.quantized import q8_dequantize, q8_quantize


def quantize_roundtrip(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (dequantized int8 approximation, residual error)."""
    q = q8_quantize(x)
    approx = q8_dequantize(q, x.shape).astype(x.dtype)
    return approx, (x - approx)


def compressed_psum(x: jax.Array, axis: str,
                    error: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8-compressed psum over ``axis`` with error feedback.

    ``error`` is this worker's residual buffer from the previous step
    (same shape as x; zeros at step 0)."""
    compensated = x + error
    approx, new_error = quantize_roundtrip(compensated)
    return jax.lax.psum(approx, axis), new_error


def compressed_tree_psum(tree, axis: str, error_tree):
    """Tree-mapped compressed_psum; returns (summed tree, new errors)."""
    flat_x, tdef = jax.tree_util.tree_flatten(tree)
    flat_e = jax.tree_util.tree_leaves(error_tree)
    out = [compressed_psum(x, axis, e) for x, e in zip(flat_x, flat_e)]
    summed = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    errs = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return summed, errs
