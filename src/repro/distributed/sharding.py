"""Logical-axis sharding rules (MaxText-style).

Models annotate tensors with *logical* axis names; a rules table maps
them to mesh axes.  Changing parallelism = changing the table, never the
model code.  The production mesh axes (launch/mesh.py):

  pod    DP across pods (grad all-reduce crosses the pod axis only)
  data   FSDP within a pod (params/opt sharded, gathered per layer)
  model  TP / EP within a pod

Default rules:
  batch        -> ("pod", "data")   activations: batch sharded
  vocab        -> "model"           embedding/logits TP
  d_model      -> None              activations replicated on feature dim
  heads        -> "model"           attention TP over query heads
  kv_heads     -> "model"           GQA KV TP (GSPMD pads non-divisible)
  q_dim/kv_dim -> "model"           fused projections (head*dim) TP
  d_ff         -> "model"           MLP TP
  experts      -> "model"           MoE EP
  d_inner      -> "model"           SSM inner TP
  fsdp         -> "data"            parameter FSDP axis (largest dim)
  seq          -> None              (sequence parallelism: set to "model")
  layers       -> None              scan axis, never sharded
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

LOGICAL_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "vocab": "model",
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "q_dim": "model",
    "kv_dim": "model",
    "d_ff": "model",
    "experts": "model",
    "d_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "head_dim": None,
    "fsdp": "data",
    "layers": None,
    "enc_seq": None,
    "img_seq": None,
    # context parallelism inside chunked attention: the query-seq dim of
    # the flash accumulator shards over model (kv-head counts rarely
    # divide a 16-way axis; 32k sequences always do)
    "attn_q_seq": "model",
}

_local = threading.local()


def get_rules() -> Rules:
    return getattr(_local, "rules", LOGICAL_RULES)


@contextlib.contextmanager
def set_rules(overrides: Rules):
    """Scoped rule overrides (used by the perf hillclimb to flip, e.g.,
    attention to sequence-parallel for one compile)."""
    base = dict(get_rules())
    base.update(overrides)
    prev = getattr(_local, "rules", None)
    _local.rules = base
    try:
        yield
    finally:
        if prev is None:
            del _local.rules
        else:
            _local.rules = prev


def logical_to_mesh_spec(logical_axes: Tuple[Optional[str], ...],
                         mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec, dropping
    mesh axes that don't exist in the current mesh (lets the same model
    code run on 1-device CPU and the 512-chip production mesh)."""
    rules = get_rules()
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    spec = []
    used = set()
    for ax in logical_axes:
        if ax is None:
            spec.append(None)
            continue
        target = rules.get(ax)
        if target is None:
            spec.append(None)
            continue
        multi = isinstance(target, tuple)
        if not multi:
            target = (target,)
        present = tuple(t for t in target
                        if (mesh_axes is None or t in mesh_axes)
                        and t not in used)
        used.update(present)
        if not present:
            spec.append(None)
        elif multi:
            # multi-axis rules keep tuple form even when the mesh drops
            # all but one axis: ("pod","data") -> ("data",) — a sharded
            # dim stays visibly distinct from a rule that named one axis
            spec.append(present)
        else:
            spec.append(present[0])
    return P(*spec)


def shard_constraint(x: jax.Array, *logical_axes: Optional[str],
                     mesh: Optional[Mesh] = None) -> jax.Array:
    """with_sharding_constraint against the logical rules.  No-op when
    no mesh is active or the mesh has a single device (CPU tests).

    Per-axis legalization: mesh axes that don't divide the dimension are
    dropped (e.g. kv_heads=8 on a 16-way model axis) instead of failing
    the whole constraint — a silent whole-constraint failure is how the
    flash accumulator ended up replicated at 21.5 GiB/device."""
    try:
        active = mesh
        if active is None:
            # rely on the jit-scope mesh: use unconstrained spec lookup
            from jax._src import mesh as mesh_lib
            env_mesh = mesh_lib.thread_resources.env.physical_mesh
            if env_mesh.empty or env_mesh.size <= 1:
                return x
            active = env_mesh
        spec = logical_to_mesh_spec(tuple(logical_axes), active)
        legal = []
        for i, ax in enumerate(spec):
            if ax is None or i >= x.ndim:
                legal.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            keep, prod = [], 1
            for a in axes:
                size = active.shape[a]
                if x.shape[i] % (prod * size) == 0:
                    keep.append(a)
                    prod *= size
            legal.append(tuple(keep) if len(keep) > 1
                         else (keep[0] if keep else None))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(active, P(*legal)))
    except Exception:
        return x


def named_sharding(mesh: Mesh, *logical_axes: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_mesh_spec(tuple(logical_axes), mesh))


#: Mesh axes that carry data residency — a corpus shard lives on one
#: coordinate of their product (DP across pods, FSDP/data within one).
#: The query runtime's PlacementMap derives its host count from these.
RESIDENCY_AXES: Tuple[str, ...] = ("pod", "data")


def data_host_count(mesh) -> int:
    """Number of data-resident hosts a mesh implies: the product of the
    residency axes present in it (``pod`` x ``data``; axes absent from
    the mesh contribute 1).  Accepts a concrete ``Mesh`` or an
    ``AbstractMesh`` — placement only needs the shape, so simulated
    topologies never have to allocate devices."""
    shape = dict(mesh.shape)
    n = 1
    for ax in RESIDENCY_AXES:
        n *= int(shape.get(ax, 1))
    return n


def mesh_axis_size(axis: str) -> Optional[int]:
    """Size of a mesh axis in the ambient jit mesh (None outside)."""
    try:
        from jax._src import mesh as mesh_lib
        env_mesh = mesh_lib.thread_resources.env.physical_mesh
        if env_mesh.empty:
            return None
        return env_mesh.shape.get(axis)
    except Exception:
        return None
