"""Sharded checkpointing with atomic commit and elastic restore.

Layout:
    <dir>/step_<N>.tmp-<nonce>/      (written)
    <dir>/step_<N>/                  (atomically renamed on completion)
        manifest.json                tree structure + shapes + dtypes
        leaf_<i>_chunk_<j>.npy       leaf i split along axis 0 into chunks

Design points for the 1000+-node story:
  * Chunked leaves emulate per-host shard files: on a real multi-host
    mesh each host writes its addressable shards; the manifest format is
    the same, so restore logic doesn't care who wrote what.
  * Restore reassembles full arrays then device_puts with the *target*
    sharding — a checkpoint taken on a (16,16) mesh restores onto
    (2,16,16) or a single CPU device (elastic scaling / failover).
  * Atomic rename means a crash mid-write never corrupts the latest
    complete checkpoint; ``latest_step`` only sees committed dirs.
  * An async mode hands the (host-synced) arrays to a writer thread so
    the train loop overlaps checkpoint I/O with compute.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import uuid
from typing import Any, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    chunk_elems: int = 1 << 24) -> str:
    """Blocking save; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp-{uuid.uuid4().hex[:8]}")
    os.makedirs(tmp)
    flat, treedef = _flatten_with_paths(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(flat):
        arr = np.asarray(leaf)
        n_chunks = max(1, -(-arr.size // chunk_elems)) if arr.ndim > 0 else 1
        rows = arr.shape[0] if arr.ndim > 0 else 1
        n_chunks = min(n_chunks, max(rows, 1))
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "chunks": n_chunks}
        if arr.ndim == 0 or n_chunks == 1:
            np.save(os.path.join(tmp, f"leaf_{i}_chunk_0.npy"), arr)
        else:
            for j, part in enumerate(np.array_split(arr, n_chunks, axis=0)):
                np.save(os.path.join(tmp, f"leaf_{i}_chunk_{j}.npy"), part)
        manifest["leaves"].append(entry)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_checkpoint(directory: str, step: int, like: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    jax.sharding.Sharding for elastic placement."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(flat_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(flat_like)}")
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat_like))
    out: List[Any] = []
    for i, (ref, entry) in enumerate(zip(flat_like, manifest["leaves"])):
        parts = [np.load(os.path.join(path, f"leaf_{i}_chunk_{j}.npy"))
                 for j in range(entry["chunks"])]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        if flat_sh[i] is not None:
            out.append(jax.device_put(arr, flat_sh[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


class CheckpointManager:
    """Async checkpointing + retention.

    ``save`` synchronously snapshots arrays to host (cheap vs device
    compute) and queues the file I/O on a writer thread.  ``wait()``
    blocks until all queued writes commit (call before exit)."""

    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._lock = threading.Lock()
        self._pending: List[threading.Thread] = []

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if self.async_write:
            t = threading.Thread(target=work, daemon=True)
            with self._lock:
                self._pending.append(t)
            t.start()
        else:
            work()

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    def restore_latest(self, like: Any, shardings: Optional[Any] = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, like, shardings)

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.directory)
            if (m := _STEP_RE.match(d)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
